"""FoldEngine serving contract: bucketed compile cache, scheduler, plan
routing (ISSUE 4 acceptance criteria; marker: serve)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import model as af2
from repro.core.config import af2_tiny
from repro.data.protein import protein_sample
from repro.parallel.plan import ParallelPlan, PlanError
from repro.serve import FoldEngine, FoldRequest
from repro.serve import fold_steps as fs

from util import randomize, run_subprocess

pytestmark = pytest.mark.serve

BUCKETS = [fs.Bucket(8, 4, 6), fs.Bucket(16, 8, 12)]


def _params(cfg, seed=0):
    return randomize(af2.init_params(jax.random.PRNGKey(seed), cfg),
                     jax.random.PRNGKey(seed + 1))


def _request(cfg, rid, r, s, se):
    c = dataclasses.replace(cfg, n_res=r, n_seq=s, n_extra_seq=se)
    smp = protein_sample(jax.random.PRNGKey(100 + rid), c)
    feats = {k: np.asarray(smp[k]) for k in fs.REQUEST_FEATURE_KEYS}
    return FoldRequest(rid=rid, features=feats)


# ---------------------------------------------------------------------------
# Bucket table mechanics
# ---------------------------------------------------------------------------

def test_bucket_for_picks_smallest_cover():
    cfg = af2_tiny()
    small = _request(cfg, 0, 6, 4, 5).features
    exact = _request(cfg, 1, 8, 4, 6).features
    big = _request(cfg, 2, 9, 4, 6).features
    assert fs.bucket_for(BUCKETS, small) == BUCKETS[0]
    assert fs.bucket_for(BUCKETS, exact) == BUCKETS[0]
    assert fs.bucket_for(BUCKETS, big) == BUCKETS[1]


def test_bucket_for_actionable_error():
    cfg = af2_tiny()
    huge = _request(cfg, 0, 32, 4, 6).features
    with pytest.raises(ValueError, match="bucket table"):
        fs.bucket_for(BUCKETS, huge)


def test_pad_to_bucket_masks_and_shapes():
    cfg = af2_tiny()
    feats = _request(cfg, 0, 6, 4, 5).features
    padded = fs.pad_to_bucket(feats, BUCKETS[0])
    assert padded["target_feat"].shape[0] == 8
    assert padded["msa_feat"].shape[:2] == (4, 8)
    assert padded["extra_msa_feat"].shape[:2] == (6, 8)
    np.testing.assert_array_equal(padded["res_mask"],
                                  [1, 1, 1, 1, 1, 1, 0, 0])
    assert padded["msa_row_mask"].sum() == 4
    assert padded["extra_row_mask"].sum() == 5
    with pytest.raises(ValueError, match="does not fit"):
        fs.pad_to_bucket(_request(cfg, 1, 12, 4, 5).features, BUCKETS[0])


def test_stack_padded_fills_micro_batch():
    cfg = af2_tiny()
    p = fs.pad_to_bucket(_request(cfg, 0, 6, 4, 5).features, BUCKETS[0])
    batch = fs.stack_padded([p], 3)
    assert batch["target_feat"].shape[0] == 3
    np.testing.assert_array_equal(batch["res_mask"][0], batch["res_mask"][2])
    with pytest.raises(ValueError, match="micro-batch"):
        fs.stack_padded([p, p], 1)


def test_predict_output_keys_pinned():
    """fold_steps' shard_map out_specs template must track predict()."""
    cfg = af2_tiny()
    params = _params(cfg)
    s = _request(cfg, 0, cfg.n_res, cfg.n_seq, cfg.n_extra_seq).features
    batch = {k: jnp.asarray(v)[None] for k, v in s.items()}
    out = af2.predict(params, cfg, batch, max_recycle=1)
    assert set(out) == set(fs.PREDICT_OUTPUT_KEYS)


# ---------------------------------------------------------------------------
# The serving contract (acceptance criterion): mixed-length queue, compile
# count <= buckets used, padded == unpadded per bucket
# ---------------------------------------------------------------------------

def test_mixed_queue_compiles_once_per_bucket_and_matches_unpadded():
    cfg = af2_tiny()
    params = _params(cfg)
    # 4 distinct lengths spanning both buckets
    reqs = [_request(cfg, 0, 6, 4, 5), _request(cfg, 1, 12, 6, 10),
            _request(cfg, 2, 8, 3, 6), _request(cfg, 3, 16, 8, 12),
            _request(cfg, 4, 5, 4, 4)]
    eng = FoldEngine(cfg, params, buckets=BUCKETS, micro_batch=2,
                     max_recycle=2, tol=0.0, dtype=jnp.float32)
    done = eng.run(reqs)
    assert sorted(done) == [0, 1, 2, 3, 4]
    assert eng.compile_misses <= len(BUCKETS)
    assert eng.compile_misses == 2          # both buckets actually used
    # re-serving the same traffic never compiles again
    done2 = eng.run(reqs)
    assert eng.compile_misses == 2
    for rid in done:
        np.testing.assert_array_equal(done[rid].coords, done2[rid].coords)

    # per-bucket padded-vs-unpadded equivalence: engine result == direct
    # unpadded predict at the request's native shapes
    for req in reqs:
        r, s, se = fs.request_shapes(req.features)
        c = dataclasses.replace(cfg, n_res=r, n_seq=s, n_extra_seq=se)
        batch = {k: jnp.asarray(v)[None] for k, v in req.features.items()}
        ref = af2.predict(params, c, batch, max_recycle=2, tol=0.0,
                          dtype=jnp.float32)
        got = done[req.rid]
        np.testing.assert_allclose(got.coords,
                                   np.asarray(ref["coords"][0]), atol=1e-4)
        np.testing.assert_allclose(got.plddt,
                                   np.asarray(ref["plddt"][0]), atol=1e-3)
        assert got.coords.shape == (r, 3)
        assert got.contact_probs.shape == (r, r)


def test_engine_stats_and_adaptive_budget():
    cfg = af2_tiny()
    params = _params(cfg)
    reqs = [_request(cfg, i, 6 + i, 4, 5) for i in range(3)]
    eng = FoldEngine(cfg, params, buckets=BUCKETS, micro_batch=2,
                     max_recycle=3, tol=1.1, dtype=jnp.float32)
    done = eng.run(reqs)
    assert eng.stats["requests"] == 3
    # tol > 1: every sample converges after one cycle — the scheduler's
    # recycle ledger shows the saved budget
    assert all(r.n_recycles == 1 and r.converged for r in done.values())
    assert eng.stats["recycles_run"] == 3
    assert eng.stats["recycles_budget"] == 9


# ---------------------------------------------------------------------------
# Plan-aware routing
# ---------------------------------------------------------------------------

def test_plan_routing_and_inference_normalization():
    cfg = af2_tiny()
    params = _params(cfg)
    long_plan = ParallelPlan(branch=2, variant="parallel", remat="block")
    eng = FoldEngine(cfg, params, buckets=BUCKETS, long_plan=long_plan,
                     long_threshold=16)
    # for_inference folds branch into data and drops remat
    assert eng.long_plan.branch == 1
    assert eng.long_plan.data == 2
    assert eng.long_plan.remat == "none"
    assert eng.plan_for(BUCKETS[0]) is eng.plan
    assert eng.plan_for(BUCKETS[1]) is eng.long_plan


def test_for_inference_drops_pod_and_compression():
    p = ParallelPlan(pod=2, data=2, branch=2, dap=4, variant="parallel",
                     compress_pod_grads=True, remat="dots")
    q = p.for_inference()
    assert (q.pod, q.data, q.branch, q.dap) == (1, 8, 1, 4)
    assert q.remat == "none" and not q.compress_pod_grads
    assert q.n_devices == p.n_devices


def test_indivisible_dap_bucket_raises_actionable():
    cfg = af2_tiny()
    params = _params(cfg)
    # dap=3 divides nothing in the tiny shapes -> PlanError from validate
    eng = FoldEngine(cfg, params, buckets=[fs.Bucket(16, 8, 12)],
                     plan=ParallelPlan(dap=3), devices=None)
    with pytest.raises(PlanError, match="dap"):
        eng.step_for(eng.buckets[0])


@pytest.mark.slow
def test_sharded_fold_matches_serial_subprocess():
    """data x dap inference plans serve the same folds as a single device
    (long bucket routed through the DAP block_fn inside shard_map)."""
    run_subprocess("""
import dataclasses, jax, numpy as np
import jax.numpy as jnp
from repro.core.config import af2_tiny
from repro.core import model as af2
from repro.data.protein import protein_sample
from repro.parallel.plan import ParallelPlan
from repro.serve import FoldEngine, FoldRequest
from repro.serve import fold_steps as fs

cfg = af2_tiny()
params = af2.init_params(jax.random.PRNGKey(0), cfg)
buckets = [fs.Bucket(8, 4, 6), fs.Bucket(16, 8, 12)]

def req(rid, r, s, se):
    c = dataclasses.replace(cfg, n_res=r, n_seq=s, n_extra_seq=se)
    smp = protein_sample(jax.random.PRNGKey(100 + rid), c)
    return FoldRequest(rid=rid, features={
        k: np.asarray(smp[k]) for k in fs.REQUEST_FEATURE_KEYS})

reqs = [req(0, 6, 4, 5), req(1, 16, 8, 12), req(2, 12, 8, 10)]
kw = dict(buckets=buckets, micro_batch=2, max_recycle=1, tol=0.0,
          dtype=jnp.float32)
sharded = FoldEngine(cfg, params, plan=ParallelPlan(data=4),
                     long_plan=ParallelPlan(data=2, dap=2),
                     long_threshold=16, **kw)
serial = FoldEngine(cfg, params, devices=jax.devices()[:1], **kw)
a, b = sharded.run(reqs), serial.run(reqs)
assert sharded.compile_misses == 2
for rid in a:
    np.testing.assert_allclose(a[rid].coords, b[rid].coords, atol=2e-4)
    np.testing.assert_allclose(a[rid].plddt, b[rid].plddt, atol=1e-3)
print("sharded fold == serial fold")
""", devices=4)
