"""Shared test helpers."""
import subprocess
import sys
import textwrap

import jax


def randomize(params, key, scale=0.02):
    """Replace AF2's zero-inits with small noise so equivalence tests are
    non-vacuous (at init all residual updates are exactly zero)."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(key, len(leaves))
    new = [l + scale * jax.random.normal(k, l.shape, l.dtype)
           for l, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, new)


def run_subprocess(code: str, *, devices: int = 8, timeout: int = 560) -> str:
    """Run test code in a fresh interpreter with N fake XLA host devices
    (the main pytest process must keep seeing exactly 1 device)."""
    prologue = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import sys
        sys.path.insert(0, {str('src')!r})
    """)
    proc = subprocess.run(
        [sys.executable, "-c", prologue + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, cwd=_repo_root())
    assert proc.returncode == 0, (
        f"subprocess failed:\nSTDOUT:\n{proc.stdout[-3000:]}\n"
        f"STDERR:\n{proc.stderr[-3000:]}")
    return proc.stdout


def _repo_root():
    import pathlib
    return str(pathlib.Path(__file__).resolve().parents[1])


# The jaxpr-walking helpers delegate to the static analyzer's shared
# traversal (src/repro/analysis/static/jaxpr_walk.py) so tests and the lint
# CLI agree on what "an intermediate" is.

def iter_eqn_avals(closed_jaxpr):
    """All output avals of all eqns, recursing into sub-jaxprs (scan/map
    bodies) — shared by the peak-intermediate memory assertions."""
    from repro.analysis.static.jaxpr_walk import iter_out_avals
    for aval, _eqn, _path in iter_out_avals(closed_jaxpr):
        yield aval


def count_prims(closed_jaxpr, names):
    """Occurrences of each primitive name, recursing into sub-jaxprs
    (scan/cond/shard_map bodies) — used to pin collective counts."""
    from repro.analysis.static.jaxpr_walk import count_primitives
    return count_primitives(closed_jaxpr, names)


def max_eqn_elems(closed_jaxpr) -> int:
    """Largest eqn-output aval, in elements."""
    from repro.analysis.static.jaxpr_walk import peak_eqn_elems
    return peak_eqn_elems(closed_jaxpr)
