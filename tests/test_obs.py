"""Telemetry subsystem contract (ISSUE 9; marker: obs).

Pins the obs layer's four load-bearing guarantees:

1. registry determinism — identical recording sequences produce
   bit-identical sink rows modulo the single wall-clock field;
2. span tracer invariants — nesting (child interval inside parent), depth
   accounting, and Chrome-trace/Perfetto schema validity;
3. thin views — ``TrainRunner.history`` IS the registry's series (same
   list objects), so legacy consumers and sinks see one stream;
4. lifetime vs per-call serve counters — ``FoldEngine.stats`` accumulates
   across calls, ``last_stats`` is the most recent call's delta (the
   inflated-ratio bug this PR pins).
"""
import json

import jax
import numpy as np
import pytest

from repro.core import model as af2
from repro.core.config import af2_tiny
from repro.obs import (ConsoleSink, JsonlSink, MemorySink, MetricRegistry,
                       SpanTracer, attribution_report, describe_attribution,
                       get_tracer, parse_profile_steps, set_tracer,
                       trace_span)
from repro.obs.sinks import strip_walltimes
from repro.parallel.plan import ParallelPlan

pytestmark = pytest.mark.obs


def _cfg():
    return af2_tiny(n_evoformer=1, n_extra_msa_blocks=1, n_res=8, n_seq=4,
                    n_extra_seq=6)


# ---------------------------------------------------------------------------
# Metric registry
# ---------------------------------------------------------------------------

def _drive(reg):
    c = reg.counter("serve/requests")
    g = reg.gauge("data/stall_fraction")
    h = reg.histogram("train/step_s")
    for step in range(5):
        c.inc(2)
        g.set(0.1 * step)
        h.observe(0.5 + 0.01 * step)
        reg.record("train/loss", 3.0 - 0.1 * step, step=step)
        reg.tick(step=step)


def test_registry_determinism_bit_identical_modulo_walltime(tmp_path):
    """Same recording sequence => bit-identical JSONL modulo the wall-clock
    field — the contract that makes metric streams diffable across runs."""
    paths = [tmp_path / "a.jsonl", tmp_path / "b.jsonl"]
    for p in paths:
        reg = MetricRegistry(sinks=[JsonlSink(p)])
        _drive(reg)
        reg.close()
    a, b = [strip_walltimes(p.read_text().splitlines()) for p in paths]
    assert a == b
    assert len(a) > 10
    # and the wall-clock field is the ONLY nondeterminism: raw lines differ
    # at most in "t"
    for la, lb in zip(paths[0].read_text().splitlines(),
                      paths[1].read_text().splitlines()):
        ra, rb = json.loads(la), json.loads(lb)
        ra.pop("t"), rb.pop("t")
        assert ra == rb


def test_registry_rows_ordered_and_tick_dedups():
    sink = MemorySink()
    reg = MetricRegistry(sinks=[sink])
    _drive(reg)
    seqs = [r["seq"] for r in sink.rows]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    # an unchanged instrument is NOT re-emitted at the next tick
    reg.tick(step=99)
    kinds = [r["kind"] for r in sink.rows if r.get("step") == 99]
    assert kinds == ["tick"]


def test_registry_series_is_live_view():
    reg = MetricRegistry()
    view = reg.series("train/loss")
    reg.record("train/loss", 1.5, step=0)
    reg.record("train/loss", 1.25, step=1)
    assert view == [1.5, 1.25]
    assert reg.series("train/loss") is view


def test_registry_kind_collision_rejected():
    reg = MetricRegistry()
    reg.counter("x")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x")


def test_histogram_quantiles():
    reg = MetricRegistry()
    h = reg.histogram("lat")
    for v in range(1, 101):
        h.observe(float(v))
    p = h.payload()
    assert p["count"] == 100 and p["min"] == 1.0 and p["max"] == 100.0
    assert abs(p["p50"] - 50.5) < 1.0
    assert p["p99"] >= 99.0


def test_console_sink_prints_stall_report_every_n_steps():
    lines = []
    sink = ConsoleSink(every=2, log=lines.append, prefixes=("data/",))
    reg = MetricRegistry(sinks=[sink])
    g = reg.gauge("data/stall_fraction")
    reg.gauge("train/ignored").set(1.0)   # filtered by prefix
    for step in range(5):
        g.set(0.1 * step)
        reg.tick(step=step)
    assert len(lines) == 3                # steps 0, 2, 4
    assert "data/stall_fraction" in lines[-1]
    assert "train/ignored" not in lines[-1]


# ---------------------------------------------------------------------------
# Span tracer
# ---------------------------------------------------------------------------

def test_span_nesting_and_ordering_invariants():
    tr = SpanTracer()
    with tr.span("outer", step=1):
        with tr.span("inner_a"):
            pass
        with tr.span("inner_b"):
            pass
    inner_a, inner_b = tr.spans("inner_a")[0], tr.spans("inner_b")[0]
    outer = tr.spans("outer")[0]
    # children complete before the parent (completion-ordered event list)
    names = [e["name"] for e in tr.events]
    assert names == ["inner_a", "inner_b", "outer"]
    # child intervals nest inside the parent's
    for child in (inner_a, inner_b):
        assert child["ts"] >= outer["ts"]
        assert child["ts"] + child["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert inner_b["ts"] >= inner_a["ts"] + inner_a["dur"] - 1e-6
    assert outer["args"]["depth"] == 0
    assert inner_a["args"]["depth"] == 1
    assert outer["args"]["step"] == 1


def test_chrome_trace_schema_perfetto_loadable(tmp_path):
    """The exported JSON must carry the Chrome-trace fields Perfetto
    requires: top-level traceEvents, ph/pid/tid/ts (+dur for X events)."""
    tr = SpanTracer()
    with tr.span("step", step=0):
        with tr.span("featurize"):
            pass
    path = tmp_path / "trace.json"
    tr.save(path)
    doc = json.loads(path.read_text())
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert metas and spans
    assert any(e["name"] == "process_name" for e in metas)
    assert any(e["name"] == "thread_name" for e in metas)
    for e in spans:
        assert {"name", "ph", "ts", "dur", "pid", "tid"} <= set(e)
        assert isinstance(e["ts"], float) and e["dur"] >= 0.0
        assert isinstance(e["tid"], int)


def test_trace_span_global_fallback_and_noop():
    with trace_span("nobody-listening"):   # no tracer anywhere: no-op
        pass
    tr = SpanTracer()
    prev = set_tracer(tr)
    try:
        assert get_tracer() is tr
        with trace_span("global"):
            pass
    finally:
        set_tracer(prev)
    assert len(tr.spans("global")) == 1


def test_worker_thread_spans_get_own_tid():
    import threading
    tr = SpanTracer()
    def work():
        with tr.span("featurize"):
            pass
    t = threading.Thread(target=work, name="featurize-0")
    with tr.span("step"):
        t.start()
        t.join()
    tids = {e["name"]: e["tid"] for e in tr.spans()}
    assert tids["step"] != tids["featurize"]
    meta_names = {e["args"]["name"]
                  for e in tr.to_chrome_trace()["traceEvents"]
                  if e["ph"] == "M" and e["name"] == "thread_name"}
    assert "featurize-0" in meta_names


def test_parse_profile_steps():
    assert parse_profile_steps("3:7") == (3, 7)
    with pytest.raises(ValueError, match="A < B"):
        parse_profile_steps("7:3")


# ---------------------------------------------------------------------------
# Attribution
# ---------------------------------------------------------------------------

def test_attribution_report_fields_and_bounds():
    cfg = _cfg()
    rep = attribution_report(
        cfg, ParallelPlan(), global_batch=2, n_recycle=2.0,
        measured_step_s=0.5, stall_fraction=0.1, overhead_s=1.0,
        wall_s=10.0, step=7)
    assert rep["step"] == 7
    assert rep["predicted_step_s"] > 0
    assert rep["measured_over_predicted"] > 0
    assert rep["model_flops_per_step"] > 0
    assert rep["achieved_flops"] == pytest.approx(
        rep["model_flops_per_step"] / 0.5)
    assert 0.0 <= rep["mfu"] <= 1.0
    # goodput = 1 - stall (0.1) - overhead fraction (1/10)
    assert rep["goodput"] == pytest.approx(0.8)
    assert "ParallelPlan" in rep["plan"]
    line = describe_attribution(rep)
    assert "MFU" in line and "goodput" in line and "stall" in line


def test_predict_step_time_scales_with_batch_and_recycle():
    from repro.analysis.roofline import predict_step_time
    cfg = _cfg()
    t1 = predict_step_time(cfg, global_batch=1, n_recycle=1.0)
    t2 = predict_step_time(cfg, global_batch=2, n_recycle=1.0)
    t1r3 = predict_step_time(cfg, global_batch=1, n_recycle=3.0)
    assert t2["predicted_step_s"] == pytest.approx(
        2 * t1["predicted_step_s"])
    assert t1r3["predicted_step_s"] > t1["predicted_step_s"]
    # trunk scale folds the extra stack + structure module in: > 1
    assert t1["trunk_scale"] > 1.0
    # data sharding divides the local batch, not the model FLOPs
    t_dp = predict_step_time(cfg, global_batch=4, data=4, n_recycle=1.0)
    assert t_dp["predicted_step_s"] == pytest.approx(t1["predicted_step_s"])
    assert t_dp["model_flops_per_step"] == pytest.approx(
        4 * t1["model_flops_per_step"])


# ---------------------------------------------------------------------------
# TrainRunner integration: history-as-view + spans + attribution stream
# ---------------------------------------------------------------------------

def test_trainrunner_history_is_registry_view_and_spans_cover_stages(
        tmp_path):
    from repro.train.trainer import TrainRunner
    sink = MemorySink()
    reg = MetricRegistry(sinks=[sink])
    tr = SpanTracer()
    runner = TrainRunner(
        _cfg(), batch_size=2, seed=0, max_recycle=2, eval_every=2,
        ckpt_dir=str(tmp_path / "ck"), ckpt_every=2, obs=reg, tracer=tr,
        hlo_check=True)
    hist = runner.run(4)
    # thin views: the history lists ARE the registry series objects
    for key in ("loss", "n_recycle", "step_s", "eval", "data",
                "attribution"):
        assert hist[key] is reg.series(f"train/{key}")
    assert len(hist["loss"]) == 4
    # every loss value also reached the sink as an event row, in order
    sunk = [r["value"] for r in sink.events("train/loss")]
    assert sunk == pytest.approx(hist["loss"])
    # attribution rows at the eval cadence, with the promised fields
    assert len(hist["attribution"]) == 2
    for a in hist["attribution"]:
        assert {"measured_step_s", "predicted_step_s", "mfu", "goodput",
                "stall_fraction"} <= set(a)
    # async-overlap verdict recorded (CPU: skipped, with the reason)
    ov = reg.series("train/async_overlap_ok")
    assert len(ov) == 1
    assert ov[0]["skipped"] is True and ov[0]["reason"]
    # ONE compiled train program despite the hlo_check lowering
    assert runner.train_compiles == 1
    # spans cover the train-side stages
    names = {e["name"] for e in tr.spans()}
    assert {"featurize", "device_put", "step", "eval",
            "checkpoint"} <= names
    # step spans carry their step ids
    steps = sorted(e["args"]["step"] for e in tr.spans("step"))
    assert steps == [0, 1, 2, 3]
    # checkpoint timings flowed through the registry
    assert len(reg.series("ckpt/save_s")) >= 1


# ---------------------------------------------------------------------------
# FoldEngine: lifetime vs per-call counters (the inflated-ratio pin)
# ---------------------------------------------------------------------------

def _fold_engine(reg=None):
    from repro.serve import FoldEngine
    from repro.serve import fold_steps as fs
    cfg = _cfg()
    params = af2.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, FoldEngine(
        cfg, params, buckets=[fs.Bucket(cfg.n_res, cfg.n_seq,
                                        cfg.n_extra_seq)],
        micro_batch=2, max_recycle=2, tol=0.0, obs=reg)


def _fold_requests(cfg, n, base=0):
    from repro.data.protein import protein_sample
    from repro.serve import FoldRequest
    from repro.serve import fold_steps as fs
    reqs = []
    for i in range(n):
        smp = protein_sample(jax.random.PRNGKey(200 + base + i), cfg)
        feats = {k: np.asarray(smp[k]) for k in fs.REQUEST_FEATURE_KEYS}
        reqs.append(FoldRequest(rid=base + i, features=feats))
    return reqs


def test_fold_engine_lifetime_vs_per_call_counters():
    reg = MetricRegistry()
    cfg, eng = _fold_engine(reg)
    eng.run(_fold_requests(cfg, 2))
    first = dict(eng.last_stats)
    assert first["requests"] == 2 and first["call"] == "run"
    assert 0.0 < first["recycle_fraction"] <= 1.0
    life_after_first = dict(eng.stats)

    eng.run(_fold_requests(cfg, 2, base=10))
    second = dict(eng.last_stats)
    # per-call: the second window reports ONLY its own traffic...
    assert second["requests"] == 2
    assert second["recycles_budget"] == first["recycles_budget"]
    # ...while the lifetime view keeps accumulating (the old behavior,
    # now explicitly the lifetime series)
    assert eng.stats["requests"] == 4
    assert eng.stats["recycles_budget"] == 2 * life_after_first[
        "recycles_budget"]
    # a per-call ratio computed from last_stats does NOT inflate
    assert second["recycle_fraction"] == pytest.approx(
        second["recycles_run"] / second["recycles_budget"])
    # the registry's serve/* counters match the lifetime dict
    assert reg.counter("serve/requests").value == eng.stats["requests"]
    assert reg.counter("serve/steps").value == eng.stats["steps"]
    # one serve/call event per entry-point call
    assert len(reg.series("serve/call")) == 2
