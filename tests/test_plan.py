"""ParallelPlan: validation errors, roofline-driven auto_plan selection
(pinning the paper's Table 5/6 preferences), build products, serialization,
and checkpoint plan-mismatch detection.

Multi-device build/step tests live in tests/test_parallel_equiv.py; the
in-process tests here marked ``needs_8_devices`` only run under the tier-1b
pass (scripts/run_tier1.sh sets XLA_FLAGS=--xla_force_host_platform_device_count=8).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.roofline import estimate_block_time
from repro.core.config import af2_initial, af2_finetune, af2_tiny
from repro.parallel.plan import (BuiltPlan, ParallelPlan, PlanError,
                                 auto_plan)
from repro.train import checkpoint as ck

needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 fake devices (tier-1b pass)")


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def test_branch_extent_limited_to_two():
    with pytest.raises(PlanError, match="exactly two dependency-free"):
        ParallelPlan(branch=3).validate()


def test_bp_requires_parallel_variant():
    with pytest.raises(PlanError, match="parallel"):
        ParallelPlan(branch=2, variant="af2").validate()
    # variant can also come from the config
    with pytest.raises(PlanError, match="parallel"):
        ParallelPlan(branch=2).validate(af2_tiny(variant="multimer"))
    ParallelPlan(branch=2).validate(af2_tiny(variant="parallel"))


def test_dap_divisibility_checked_against_all_stacks():
    cfg = af2_tiny()  # n_seq=8, n_extra_seq=12, n_res=16
    with pytest.raises(PlanError, match="n_seq"):
        ParallelPlan(dap=3).validate(cfg)          # 3 divides 12 but not 8
    with pytest.raises(PlanError, match="n_extra_seq"):
        ParallelPlan(dap=8).validate(cfg)          # 8 divides 8/16 but not 12
    ParallelPlan(dap=2).validate(cfg)


def test_compress_requires_pod_axis():
    with pytest.raises(PlanError, match="pod=1"):
        ParallelPlan(compress_pod_grads=True).validate()
    ParallelPlan(pod=2, data=2, compress_pod_grads=True).validate()


def test_unknown_impl_names_rejected():
    with pytest.raises(PlanError, match="attention_impl"):
        ParallelPlan(attention_impl="flash2").validate()
    with pytest.raises(PlanError, match="remat"):
        ParallelPlan(remat="full").validate()


def test_from_flags_derives_data_extent():
    p = ParallelPlan.from_flags(8, bp=2, dap=2)
    assert (p.data, p.branch, p.dap) == (2, 2, 2)
    with pytest.raises(PlanError, match="divide"):
        ParallelPlan.from_flags(8, bp=2, dap=3)


def test_apply_to_config_sets_both_stacks():
    cfg = af2_tiny(variant="af2")
    plan = ParallelPlan(variant="parallel", attention_impl="reference",
                        remat="none")
    c2 = plan.apply_to(cfg)
    assert c2.evoformer.variant == "parallel"
    assert c2.extra.variant == "parallel"
    assert c2.extra.attention_impl == "reference"
    assert c2.remat == "none"
    # None fields leave the config untouched
    assert ParallelPlan().apply_to(cfg) is cfg


def test_serialization_roundtrip_and_unknown_fields():
    plan = ParallelPlan(pod=2, data=4, branch=2, dap=2, variant="parallel",
                        compress_pod_grads=True)
    assert ParallelPlan.from_dict(plan.to_dict()) == plan
    with pytest.raises(PlanError, match="unknown"):
        ParallelPlan.from_dict({"data": 2, "tensor_parallel": 4})
    # overlap_dap serializes (and hence lands in checkpoint manifests)
    plan = ParallelPlan(data=4, dap=2, overlap_dap=True)
    assert "overlap_dap" in plan.to_dict()
    assert ParallelPlan.from_dict(plan.to_dict()) == plan
    assert "overlap_dap=on" in plan.describe()
    assert "overlap_dap" not in ParallelPlan(data=4, dap=2).describe()


def test_overlap_dap_validation():
    cfg = af2_tiny(variant="parallel")
    ParallelPlan(data=4, dap=2, overlap_dap=True).validate(cfg)
    with pytest.raises(PlanError, match="no DAP collectives"):
        ParallelPlan(data=8, overlap_dap=True).validate(cfg)
    with pytest.raises(PlanError, match="hybrid"):
        ParallelPlan(data=2, branch=2, dap=2, overlap_dap=True).validate(cfg)
    with pytest.raises(PlanError, match="parallel"):
        ParallelPlan(dap=2, variant="af2", overlap_dap=True).validate()
    with pytest.raises(PlanError, match="parallel"):
        ParallelPlan(dap=2, overlap_dap=True).validate(af2_tiny(variant="af2"))


def test_overlap_dap_auto_resolution():
    """overlap_dap=None resolves ON exactly for pure-DAP 'parallel' groups;
    an explicit value always wins."""
    cfg = af2_tiny(variant="parallel")
    assert ParallelPlan(data=4, dap=2).resolve_overlap(cfg) is True
    assert ParallelPlan(data=4, dap=2, overlap_dap=False).resolve_overlap(cfg) is False
    assert ParallelPlan(data=2, branch=2, dap=2).resolve_overlap(cfg) is False
    assert ParallelPlan(data=8).resolve_overlap(cfg) is False
    assert ParallelPlan(data=4, dap=2).resolve_overlap(
        af2_tiny(variant="af2")) is False
    # without a config the variant is unknowable -> stay sync
    assert ParallelPlan(data=4, dap=2).resolve_overlap(None) is False
    # a plan-level variant override makes the config irrelevant
    assert ParallelPlan(data=4, dap=2, variant="parallel").resolve_overlap(
        af2_tiny(variant="af2")) is True


# ---------------------------------------------------------------------------
# auto_plan: the paper's Table 5/6 preferences, pinned
# ---------------------------------------------------------------------------

def test_auto_plan_serial_dp_when_batch_covers_devices():
    p = auto_plan(8, af2_initial(), global_batch=8)
    assert (p.data, p.branch, p.dap) == (8, 1, 1)


def test_auto_plan_prefers_bp_not_dap_at_initial_shapes():
    """Paper Table 5: at initial-training shapes (r=256, s=128) the roofline
    prefers BP over DAP for a forced 2-device group — DAP's collectives and
    lost per-op intensity outweigh its halved FLOPs."""
    cfg = af2_initial()
    p = auto_plan(256, cfg, global_batch=128)
    assert (p.branch, p.dap) == (2, 1), p
    assert estimate_block_time(cfg, bp=2, dap=1) < \
        estimate_block_time(cfg, bp=1, dap=2)


def test_auto_plan_prefers_hybrid_at_finetune_shapes():
    """Paper Table 6, re-derived under the overlap-aware comm model: the
    8-device fine-tuning group (r=384, s=512) still picks the BP x DAP
    hybrid, but the 4-device group shifts to pure overlapped DAP — hiding
    the per-block gathers behind compute beats halving them via BP (the
    long-sequence shift the FastFold duplex schedule predicts).  The paper's
    original sync-schedule preference is pinned with overlap=False."""
    cfg = af2_finetune()
    p4 = auto_plan(512, cfg, global_batch=128)
    assert (p4.branch, p4.dap) == (1, 4), p4
    p8 = auto_plan(1024, cfg, global_batch=128)
    assert (p8.branch, p8.dap) == (2, 4), p8
    # sync schedule (Table 6 as printed): hybrid beats pure DAP at 4 devices
    assert estimate_block_time(cfg, bp=2, dap=2, overlap=False) < \
        estimate_block_time(cfg, bp=1, dap=4, overlap=False)
    # ...and the overlapped pure-DAP beats the hybrid, driving the flip
    # (the hybrid keeps the sync schedule: cond-arm dispatch precludes the
    # shared prefetch carry)
    assert estimate_block_time(cfg, bp=1, dap=4, overlap=True) < \
        estimate_block_time(cfg, bp=2, dap=2, overlap=False)


def test_auto_plan_dap_wins_back_at_finetune_group2():
    """Paper Table 5's flip side: at fine-tuning shapes a 2-device group
    prefers DAP (BP's exchange outweighs its balanced-branch win)."""
    p = auto_plan(256, af2_finetune(), global_batch=128)
    assert (p.branch, p.dap) == (1, 2), p


def test_auto_plan_respects_variant_and_divisibility():
    # serial variant: BP infeasible, group 2 must fall to DAP
    p = auto_plan(16, af2_finetune(variant="af2"), global_batch=8)
    assert (p.branch, p.dap) == (1, 2)
    # no feasible split at all -> actionable error
    with pytest.raises(PlanError, match="no feasible plan"):
        auto_plan(3, af2_tiny(), global_batch=1)


def test_auto_plan_pod_extent():
    p = auto_plan(16, af2_initial(), global_batch=8, pod=2)
    assert p.pod == 2 and p.n_devices == 16
    assert p.pod * p.data <= 8


# ---------------------------------------------------------------------------
# build products
# ---------------------------------------------------------------------------

def test_af2_small_preset_is_really_20m_params():
    """examples/train_af2.py --preset small promises a ~20M-param model
    (it used to silently alias tiny's 83k params)."""
    from repro.core import model as af2
    from repro.core.config import af2_small
    shapes = jax.eval_shape(
        lambda: af2.init_params(jax.random.PRNGKey(0), af2_small()))
    n = sum(int(s.size) for s in jax.tree_util.tree_leaves(shapes))
    assert 18e6 < n < 22e6, f"{n:,} params"


def test_build_serial_single_device():
    built = ParallelPlan().build(jax.devices()[:1], cfg=af2_tiny())
    assert isinstance(built, BuiltPlan)
    assert dict(built.mesh.shape) == {"data": 1}
    assert built.block_fn is None and built.stack_io is None
    assert built.sync_axes == ()


def test_build_device_count_mismatch_is_actionable():
    with pytest.raises(PlanError, match="covers 4 devices"):
        ParallelPlan(data=2, branch=2).build(jax.devices()[:1])


def test_build_rejects_invalid_plan_before_touching_devices():
    with pytest.raises(PlanError, match="exactly two"):
        ParallelPlan(branch=4).build(jax.devices()[:1])


def test_metadata_fingerprint():
    built = ParallelPlan().build(jax.devices()[:1], cfg=af2_tiny())
    meta = built.metadata()
    assert meta["plan"]["data"] == 1
    assert meta["mesh_fingerprint"]["n_devices"] == 1
    assert "axes" in meta["mesh_fingerprint"]


@needs_8_devices
def test_build_hybrid_mesh_axes():
    plan = ParallelPlan(data=2, branch=2, dap=2)
    built = plan.build(jax.devices(), cfg=af2_tiny())
    assert dict(built.mesh.shape) == {"data": 2, "branch": 2, "dap": 2}
    assert built.sync_axes == ("branch", "dap")
    assert built.block_fn is not None and built.stack_io is not None
    assert built.batch_spec == jax.sharding.PartitionSpec("data")


@needs_8_devices
def test_build_refactors_production_model_axis():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    plan = ParallelPlan.for_mesh(mesh, branch=2, dap=2)
    built = plan.build(mesh, cfg=af2_tiny())
    assert dict(built.mesh.shape) == {"data": 2, "branch": 2, "dap": 2}
    # bad factorization is refused with the extents in the message
    with pytest.raises(PlanError, match="model"):
        ParallelPlan.for_mesh(mesh, branch=2, dap=4).build(mesh)


# ---------------------------------------------------------------------------
# checkpoint plan metadata
# ---------------------------------------------------------------------------

def _state():
    return {"w": jnp.arange(4.0)}


def test_checkpoint_records_and_accepts_matching_plan(tmp_path):
    built = ParallelPlan().build(jax.devices()[:1], cfg=af2_tiny())
    mgr = ck.CheckpointManager(tmp_path, async_save=False,
                               plan_meta=built.metadata())
    mgr.save(3, _state())
    stored = ck.checkpoint_meta(tmp_path)
    assert stored["plan"] == built.plan.to_dict()
    restored, step = mgr.restore_latest(_state())
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(_state()["w"]))


def test_checkpoint_refuses_mismatched_plan(tmp_path):
    built = ParallelPlan().build(jax.devices()[:1], cfg=af2_tiny())
    ck.CheckpointManager(tmp_path, async_save=False,
                         plan_meta=built.metadata()).save(1, _state())
    other = dict(built.metadata())
    other["plan"] = {**other["plan"], "dap": 4, "branch": 2}
    mgr2 = ck.CheckpointManager(tmp_path, async_save=False, plan_meta=other)
    with pytest.raises(ck.PlanMismatchError, match="dap"):
        mgr2.restore_latest(_state())
    # explicit adapt restores anyway (elastic/mesh-agnostic format)
    restored, step = mgr2.restore_latest(_state(), adapt_plan=True)
    assert step == 1


def test_checkpoint_mesh_fingerprint_mismatch_alone_is_allowed(tmp_path):
    built = ParallelPlan().build(jax.devices()[:1], cfg=af2_tiny())
    ck.CheckpointManager(tmp_path, async_save=False,
                         plan_meta=built.metadata()).save(1, _state())
    grown = dict(built.metadata())
    grown["mesh_fingerprint"] = {**grown["mesh_fingerprint"],
                                 "n_devices": 64, "axes": {"data": 64}}
    mgr = ck.CheckpointManager(tmp_path, async_save=False, plan_meta=grown)
    _, step = mgr.restore_latest(_state())  # elastic restart: no error
    assert step == 1


def test_checkpoint_without_meta_stays_compatible(tmp_path):
    ck.save_checkpoint(tmp_path, 2, _state())   # legacy: no meta
    built = ParallelPlan().build(jax.devices()[:1], cfg=af2_tiny())
    mgr = ck.CheckpointManager(tmp_path, async_save=False,
                               plan_meta=built.metadata())
    _, step = mgr.restore_latest(_state())      # nothing stored -> no check
    assert step == 2
