"""Checkpoint/restart + fault-tolerance machinery."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ck


def _tree():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones((3,), jnp.bfloat16)},
            "step_stuff": (jnp.asarray(3), jnp.asarray(2.5))}


def test_roundtrip(tmp_path):
    tree = _tree()
    ck.save_checkpoint(tmp_path, 7, tree)
    restored, step = ck.restore_checkpoint(tmp_path, tree)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_and_keep_n(tmp_path):
    mgr = ck.CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in (1, 5, 9):
        mgr.save(s, _tree())
    assert ck.latest_step(tmp_path) == 9
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir())
    assert steps == [5, 9]  # keep-2 GC


def test_async_save_and_wait(tmp_path):
    mgr = ck.CheckpointManager(tmp_path, keep=3, async_save=True)
    mgr.save(1, _tree())
    mgr.wait()
    assert ck.latest_step(tmp_path) == 1


def test_structure_mismatch_rejected(tmp_path):
    ck.save_checkpoint(tmp_path, 0, _tree())
    with pytest.raises(ValueError, match="structure mismatch"):
        ck.restore_checkpoint(tmp_path, {"other": jnp.zeros(3)})


def test_atomicity_no_partial_dirs(tmp_path):
    ck.save_checkpoint(tmp_path, 3, _tree())
    names = [p.name for p in tmp_path.iterdir()]
    assert names == ["step_0000000003"]  # no tmp.* residue


def test_elastic_reshard_restore(tmp_path):
    """Checkpoint written under one sharding restores onto another (the
    shrunk/grown-mesh restart path).  On 1 CPU device we exercise the
    device_put re-shard call with fresh shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = _tree()
    ck.save_checkpoint(tmp_path, 2, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree)
    restored, _ = ck.restore_checkpoint(tmp_path, tree, shardings=sh)
    leaf = jax.tree_util.tree_leaves(restored)[0]
    assert leaf.sharding == NamedSharding(mesh, P())


def test_resume_continues_training(tmp_path):
    """Crash/restart: state after N steps == state after k steps + restore +
    (N-k) steps — the checkpoint path is lossless."""
    from repro.train.optim import adamw
    opt = adamw(0.1)
    params = {"x": jnp.array([4.0])}

    def step(state):
        g = jax.grad(lambda p: jnp.sum((p["x"] - 1.0) ** 2))(state["params"])
        p, o = opt.update(g, state["opt"], state["params"])
        return {"params": p, "opt": o}

    state = {"params": params, "opt": opt.init(params)}
    for i in range(5):
        state = step(state)
        if i == 2:
            ck.save_checkpoint(tmp_path, i, state)
    # restart from step 2
    state2, _ = ck.restore_checkpoint(
        tmp_path, {"params": params, "opt": opt.init(params)})
    for _ in range(2):
        state2 = step(state2)
    np.testing.assert_allclose(np.asarray(state["params"]["x"]),
                               np.asarray(state2["params"]["x"]), rtol=1e-6)


def test_step_watchdog_flags_stragglers():
    flagged = []
    wd = ck.StepWatchdog(threshold=3.0,
                         on_straggler=lambda s, dt, ema: flagged.append(s))
    for i in range(5):
        wd.start_step()
        time.sleep(0.01)
        wd.end_step(i)
    wd.start_step()
    time.sleep(0.2)  # straggler
    assert wd.end_step(99) is True
    assert flagged == [99]
    # EMA not poisoned by the outlier
    assert wd.ema < 0.05
