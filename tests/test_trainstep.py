"""LM train-step factory: loss descends, microbatch == full batch."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import dense
from repro.models.lmconfig import LMConfig
from repro.train.optim import adamw, sgd
from repro.train.trainstep import make_lm_train_step, sanitize_spec


def _setup(microbatch=None):
    cfg = LMConfig(arch_id="t", family="dense", n_layer=2, d_model=32,
                   n_head=2, n_kv_head=2, d_ff=64, vocab=67,
                   scan_layers=True, remat="none", attention_chunk=8)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    opt = sgd(0.1)
    step, state_sh, batch_sh = make_lm_train_step(
        dense, cfg, opt, mesh, microbatch=microbatch)
    params = dense.init_params(jax.random.PRNGKey(0), cfg)
    state = {"params": params, "opt": opt.init(params)}
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    return cfg, step, state, batch


def test_loss_decreases():
    cfg, step, state, batch = _setup()
    fn = jax.jit(step)
    losses = []
    for _ in range(8):
        state, m = fn(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_microbatch_equals_full_batch():
    _, step_full, state_f, batch = _setup()
    _, step_micro, state_m, _ = _setup(microbatch=2)
    sf, mf = jax.jit(step_full)(state_f, batch)
    sm, mm = jax.jit(step_micro)(state_m, batch)
    np.testing.assert_allclose(float(mf["loss"]), float(mm["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(sf["params"]),
                    jax.tree_util.tree_leaves(sm["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_sanitize_spec_drops_indivisible():
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    assert sanitize_spec(P("data", "model"), (32, 48), FakeMesh()) == \
        P("data", "model")
    assert sanitize_spec(P("data", None), (1, 5), FakeMesh()) == P(None, None)
    assert sanitize_spec(P(("data", "model"),), (256,), FakeMesh()) == \
        P(("data", "model"))
    # 64 and 16 divide only the first factor of (data=16, model=16)
    assert sanitize_spec(P(("data", "model"),), (64,), FakeMesh()) == P("data")
    assert sanitize_spec(P(("data", "model"),), (16,), FakeMesh()) == P("data")


def test_af2_model_flops_sane():
    from repro.analysis.roofline import af2_model_flops
    from repro.core.config import af2_initial, af2_finetune
    f_init = af2_model_flops(af2_initial())
    f_ft = af2_model_flops(af2_finetune())
    assert f_ft > 2 * f_init  # fine-tuning shapes are much bigger
    assert 1e12 < f_init < 1e16
