"""Dry-run machinery self-test on a small fake mesh (subprocess).

Exercises the exact code path of the 512-device production dry-run — mesh
construction, sharded ShapeDtypeStruct lowering, compile, memory/cost/
collective analysis, per-layer probe extrapolation — on a 2x4 mesh with a
reduced arch so it runs in seconds.
"""
import json

import pytest

from tests.util import run_subprocess

pytestmark = pytest.mark.slow


def test_dryrun_cell_small_mesh(tmp_path):
    out = run_subprocess(f"""
import os
os.environ["REPRO_DRYRUN_MESH"] = "2x4"
os.environ["REPRO_DRYRUN_OUT"] = {str(tmp_path)!r}
import jax  # init BEFORE importing dryrun so its 512-device flag is inert
assert len(jax.devices()) == 8
from repro.launch import dryrun
from repro import configs as cfglib

cfg = cfglib.get_smoke_config("glm4-9b", scan_layers=True, n_layer=6,
                              fsdp=True)
rec = dryrun.run_lm_cell("glm4-9b", "train_4k", False, probes=True,
                         cfg_override=cfg.__class__(**{{
                             **cfg.__dict__, "vocab": 256}}))
assert rec["status"] == "ok", rec
assert rec["full"]["per_device_flops"] > 0
assert rec["full"]["memory"]["temp_bytes"] > 0
assert rec["probe"]["extrapolated"]["per_device_flops"] > \
    rec["probe"]["l2"]["per_device_flops"]
assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
assert rec["roofline"]["useful_flops_ratio"] > 0
# grad-sync collectives must appear in the compiled train step
assert rec["full"]["collective_bytes_static"] > 0, rec["full"]["collectives"]
print("dryrun small cell ok:", rec["roofline"]["dominant"])
""", devices=8, timeout=560)
    assert "dryrun small cell ok" in out


def test_dryrun_decode_cell_small_mesh(tmp_path):
    out = run_subprocess(f"""
import os
os.environ["REPRO_DRYRUN_MESH"] = "2x4"
os.environ["REPRO_DRYRUN_OUT"] = {str(tmp_path)!r}
import jax
from repro.launch import dryrun
from repro import configs as cfglib
import dataclasses

cfg = cfglib.get_smoke_config("mamba2-2.7b", scan_layers=True, n_layer=4)
shape = dataclasses.replace(cfglib.SHAPES["decode_32k"], seq_len=64,
                            global_batch=8)
mesh = dryrun._mesh(False)
fn, args = dryrun.build_lm_step(cfg, shape, mesh)
compiled = fn.lower(*args).compile()
a = dryrun.analyse(None, compiled, 8)
assert a["per_device_flops"] > 0
print("decode cell ok")
""", devices=8, timeout=560)
    assert "decode cell ok" in out
