"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

FA_CASES = [
    # (b, s, t, h, kv, d, causal, dtype, tol)
    (1, 128, 128, 4, 2, 64, True, jnp.float32, 2e-4),
    (2, 256, 256, 4, 4, 32, True, jnp.float32, 2e-4),
    (1, 128, 128, 2, 1, 128, False, jnp.float32, 2e-4),
    (1, 128, 128, 4, 2, 64, True, jnp.bfloat16, 3e-2),
]


@pytest.mark.parametrize("case", FA_CASES)
def test_flash_attention_vs_ref(case):
    b, s, t, h, kv, d, causal, dtype, tol = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, t, kv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, kv, d)).astype(dtype)
    out = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, causal))(q, k, v)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_grads():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    g1 = jax.grad(lambda q: ops.flash_attention(q, k, v, True).sum())(q)
    g2 = jax.grad(lambda q: ref.flash_attention_ref(q, k, v, causal=True).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-3, atol=1e-3)


EVO_CASES = [
    (8, 128, 4, 32, jnp.float32, 2e-4),
    (4, 256, 2, 16, jnp.float32, 2e-4),
    (2, 128, 8, 64, jnp.bfloat16, 3e-2),
]


@pytest.mark.parametrize("case", EVO_CASES)
def test_evo_attention_vs_ref(case):
    L, s, h, c, dtype, tol = case
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (L, s, h, c)).astype(dtype)
    k = jax.random.normal(ks[1], (L, s, h, c)).astype(dtype)
    v = jax.random.normal(ks[2], (L, s, h, c)).astype(dtype)
    bias = jax.random.normal(ks[3], (h, s, s)).astype(dtype)
    gate = jax.random.normal(ks[4], (L, s, h, c)).astype(dtype)
    out = jax.jit(ops.evo_attention)(q, k, v, bias, gate)
    expect = ref.evo_attention_ref(q, k, v, bias, gate)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_evo_attention_bias_grad():
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    L, s, h, c = 4, 128, 2, 32
    q, k, v, gate = (jax.random.normal(kk, (L, s, h, c)) for kk in ks[:4])
    bias = jax.random.normal(ks[4], (h, s, s))
    g1 = jax.grad(lambda b: ops.evo_attention(q, k, v, b, gate).sum())(bias)
    g2 = jax.grad(lambda b: ref.evo_attention_ref(q, k, v, b, gate).sum())(bias)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-3, atol=1e-3)


def _chunked_vjp_evo(q, k, v, bias, gate):
    """The old fallback VJP path: chunked-XLA attention + external gating."""
    from repro.nn.attention import attention_chunked
    o = attention_chunked(q, k, v, bias=bias, chunk_size=32)
    return jax.nn.sigmoid(gate.astype(jnp.float32)).astype(o.dtype) * o


def test_evo_flash_backward_matches_chunked_vjp():
    """All five gradients (q/k/v/bias/gate) from the Pallas flash backward
    kernels vs the chunked-XLA VJP, on MXU-aligned shapes."""
    L, s, h, c = 4, 128, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    q, k, v, gate = (jax.random.normal(kk, (L, s, h, c)) for kk in ks[:4])
    bias = jax.random.normal(ks[4], (h, s, s))
    w = jnp.cos(jnp.arange(c))  # non-uniform cotangent

    def loss(fn):
        return lambda *args: (fn(*args) * w).sum()

    g_flash = jax.jit(jax.grad(loss(ops.evo_attention),
                               argnums=(0, 1, 2, 3, 4)))(q, k, v, bias, gate)
    g_ref = jax.grad(loss(_chunked_vjp_evo),
                     argnums=(0, 1, 2, 3, 4))(q, k, v, bias, gate)
    for name, a, b in zip("q k v bias gate".split(), g_flash, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3, err_msg=f"d{name}")


def test_evo_flash_backward_nogate():
    from repro.nn.attention import attention_reference
    L, s, h, c = 2, 128, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(12), 4)
    q, k, v = (jax.random.normal(kk, (L, s, h, c)) for kk in ks[:3])
    bias = jax.random.normal(ks[3], (h, s, s))
    g1 = jax.jit(jax.grad(lambda q, k, v, b: ops.evo_attention_nogate(
        q, k, v, b).sum(), argnums=(0, 1, 2, 3)))(q, k, v, bias)
    g2 = jax.grad(lambda q, k, v, b: attention_reference(
        q, k, v, bias=b).sum(), argnums=(0, 1, 2, 3))(q, k, v, bias)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


def test_evo_attention_nobias_gated():
    """Gated attention with the bias add compiled out (MSA column attention
    under evo_pallas): fwd + all gradients vs the gated reference."""
    from repro.nn.attention import attention_reference
    L, s, h, c = 2, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(15), 4)
    q, k, v, gate = (jax.random.normal(kk, (L, s, h, c)) for kk in ks)

    def gated_ref(q, k, v, gate):
        o = attention_reference(q, k, v)
        return jax.nn.sigmoid(gate) * o

    out = jax.jit(ops.evo_attention_nobias)(q, k, v, gate)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(gated_ref(q, k, v, gate)),
                               rtol=2e-5, atol=2e-5)
    g1 = jax.jit(jax.grad(lambda *a: ops.evo_attention_nobias(*a).sum(),
                          argnums=(0, 1, 2, 3)))(q, k, v, gate)
    g2 = jax.grad(lambda *a: gated_ref(*a).sum(),
                  argnums=(0, 1, 2, 3))(q, k, v, gate)
    for name, a, b in zip("q k v gate".split(), g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3, err_msg=f"d{name}")


def test_evo_block_size_always_divides():
    """Regression: a non-power-of-two block request must degrade to a valid
    divisor, never to a grid that under-covers the sequence (NaN rows)."""
    from repro.kernels.flash_attention import evo_block_size, evo_attention_fwd
    for s in (8, 12, 96, 128, 250, 384):
        for cap in (1, 7, 32, 96, 128):
            b = evo_block_size(s, cap)
            assert s % b == 0 and 1 <= b <= max(cap, 1), (s, cap, b)
    ks = jax.random.split(jax.random.PRNGKey(16), 5)
    L, s, h, c = 2, 128, 2, 16
    q, k, v, gate = (jax.random.normal(kk, (L, s, h, c)) for kk in ks[:4])
    bias = jax.random.normal(ks[4], (h, s, s))
    a = evo_attention_fwd(q, k, v, bias, gate, block_q=96, block_k=96)
    b = evo_attention_fwd(q, k, v, bias, gate)
    assert np.isfinite(np.asarray(a)).all()
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


def test_evo_vjp_no_longer_calls_attention_chunked(monkeypatch):
    """The evo_attention VJP must be flash-native: poisoning the chunked-XLA
    path must not affect it (while flash_attention's LM bwd still uses it)."""
    def boom(*a, **kw):
        raise AssertionError("evo_attention VJP called attention_chunked")

    monkeypatch.setattr(ops, "attention_chunked", boom)
    ks = jax.random.split(jax.random.PRNGKey(13), 5)
    L, s, h, c = 2, 64, 2, 16
    q, k, v, gate = (jax.random.normal(kk, (L, s, h, c)) for kk in ks[:4])
    bias = jax.random.normal(ks[4], (h, s, s))
    g = jax.grad(lambda q: ops.evo_attention(q, k, v, bias, gate).sum())(q)
    assert np.isfinite(np.asarray(g)).all()
    gn = jax.grad(lambda q: ops.evo_attention_nogate(q, k, v, bias).sum())(q)
    assert np.isfinite(np.asarray(gn)).all()


def test_evo_fwd_residuals_lse():
    """Residual-mode forward must agree with the plain forward and emit the
    correct per-row log-sum-exp."""
    from repro.kernels import flash_attention as fk
    L, s, h, c = 2, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(14), 5)
    q, k, v, gate = (jax.random.normal(kk, (L, s, h, c)) for kk in ks[:4])
    bias = jax.random.normal(ks[4], (h, s, s))
    out0 = fk.evo_attention_fwd(q, k, v, bias, gate)
    out1, lse = fk.evo_attention_fwd(q, k, v, bias, gate,
                                     return_residuals=True)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(out1))
    scale = c ** -0.5
    logits = (jnp.einsum("lshc,lthc->lhst", q, k) * scale +
              bias[None]).astype(jnp.float32)
    lse_ref = jax.scipy.special.logsumexp(logits, axis=-1)   # (L, h, s)
    np.testing.assert_allclose(np.asarray(lse.reshape(L, h, s)),
                               np.asarray(lse_ref), rtol=1e-5, atol=1e-5)


def test_kernel_blocking_invariance():
    """Output must not depend on block sizes (pure tiling parameter)."""
    from repro.kernels.flash_attention import flash_attention_fwd
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    a = flash_attention_fwd(q, k, v, causal=True, block_q=128, block_k=128)
    b = flash_attention_fwd(q, k, v, causal=True, block_q=64, block_k=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)
