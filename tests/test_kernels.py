"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

FA_CASES = [
    # (b, s, t, h, kv, d, causal, dtype, tol)
    (1, 128, 128, 4, 2, 64, True, jnp.float32, 2e-4),
    (2, 256, 256, 4, 4, 32, True, jnp.float32, 2e-4),
    (1, 128, 128, 2, 1, 128, False, jnp.float32, 2e-4),
    (1, 128, 128, 4, 2, 64, True, jnp.bfloat16, 3e-2),
]


@pytest.mark.parametrize("case", FA_CASES)
def test_flash_attention_vs_ref(case):
    b, s, t, h, kv, d, causal, dtype, tol = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, t, kv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, kv, d)).astype(dtype)
    out = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, causal))(q, k, v)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_grads():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    g1 = jax.grad(lambda q: ops.flash_attention(q, k, v, True).sum())(q)
    g2 = jax.grad(lambda q: ref.flash_attention_ref(q, k, v, causal=True).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-3, atol=1e-3)


EVO_CASES = [
    (8, 128, 4, 32, jnp.float32, 2e-4),
    (4, 256, 2, 16, jnp.float32, 2e-4),
    (2, 128, 8, 64, jnp.bfloat16, 3e-2),
]


@pytest.mark.parametrize("case", EVO_CASES)
def test_evo_attention_vs_ref(case):
    L, s, h, c, dtype, tol = case
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (L, s, h, c)).astype(dtype)
    k = jax.random.normal(ks[1], (L, s, h, c)).astype(dtype)
    v = jax.random.normal(ks[2], (L, s, h, c)).astype(dtype)
    bias = jax.random.normal(ks[3], (h, s, s)).astype(dtype)
    gate = jax.random.normal(ks[4], (L, s, h, c)).astype(dtype)
    out = jax.jit(ops.evo_attention)(q, k, v, bias, gate)
    expect = ref.evo_attention_ref(q, k, v, bias, gate)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_evo_attention_bias_grad():
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    L, s, h, c = 4, 128, 2, 32
    q, k, v, gate = (jax.random.normal(kk, (L, s, h, c)) for kk in ks[:4])
    bias = jax.random.normal(ks[4], (h, s, s))
    g1 = jax.grad(lambda b: ops.evo_attention(q, k, v, b, gate).sum())(bias)
    g2 = jax.grad(lambda b: ref.evo_attention_ref(q, k, v, b, gate).sum())(bias)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-3, atol=1e-3)


def test_kernel_blocking_invariance():
    """Output must not depend on block sizes (pure tiling parameter)."""
    from repro.kernels.flash_attention import flash_attention_fwd
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    a = flash_attention_fwd(q, k, v, causal=True, block_q=128, block_k=128)
    b = flash_attention_fwd(q, k, v, causal=True, block_q=64, block_k=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)
