"""Serving: decode engine continuous batching == sequential reference."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import dense, get_model
from repro.models.lmconfig import LMConfig
from repro.serve.engine import DecodeEngine, Request


def _cfg():
    return LMConfig(arch_id="t", family="dense", n_layer=2, d_model=48,
                    n_head=4, n_kv_head=2, d_ff=96, vocab=61,
                    scan_layers=True, remat="none", attention_chunk=16)


def _greedy_reference(model, cfg, params, prompt, n_new):
    """Generate by full-recompute teacher forcing (no cache)."""
    toks = list(map(int, prompt))
    out = []
    for _ in range(n_new):
        logits = model.forward(params, cfg, jnp.asarray([toks]))
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


def test_engine_matches_no_cache_reference():
    cfg = _cfg()
    model = dense
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 6, dtype=np.int32) for _ in range(3)]
    engine = DecodeEngine(model, cfg, params, batch_slots=2, max_len=32)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    done = engine.run(reqs)
    assert set(done) == {0, 1, 2}
    for i, p in enumerate(prompts):
        expect = _greedy_reference(model, cfg, params, p, 5)
        assert done[i] == expect, f"req {i}: {done[i]} != {expect}"


def test_engine_slot_reuse():
    """More requests than slots: all finish, cache slots recycled."""
    cfg = _cfg()
    params = dense.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    engine = DecodeEngine(dense, cfg, params, batch_slots=2, max_len=32)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4, dtype=np.int32),
                    max_new_tokens=3) for i in range(5)]
    done = engine.run(reqs)
    assert set(done) == set(range(5))
    assert all(len(v) == 3 for v in done.values())


def test_cache_partition_rules_cover_all_families():
    from repro import configs as cfglib
    from repro.nn.partition import make_param_specs
    from repro.serve.steps import cache_partition_rules
    for arch in cfglib.ARCH_IDS:
        cfg = cfglib.get_smoke_config(arch)
        model = get_model(cfg)
        cache = model.init_cache(cfg, 2, 8)
        cache = {k: v for k, v in cache.items() if v is not None}
        specs = make_param_specs(cache, cache_partition_rules(cfg))
        # every array leaf got a spec of rank <= leaf rank
        for leaf, spec in zip(jax.tree_util.tree_leaves(cache),
                              jax.tree_util.tree_leaves(
                                  specs, is_leaf=lambda x: hasattr(x, "index"))):
            pass  # make_param_specs already validates ranks
