"""``hypothesis`` if installed, else a tiny deterministic fallback.

The real library is preferred (`pip install -r requirements-dev.txt`), but it
must not be a hard collection-time dependency: a missing import in one test
module aborts the whole tier-1 suite.  The fallback implements exactly the
strategy surface this suite uses — ``integers``, ``sampled_from``,
``booleans`` — and runs each ``@given`` test on a fixed pseudo-random sample
of the strategy space (seeded, so failures reproduce), trading shrinking and
coverage for zero dependencies.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        def __init__(self, sample_fn):
            self._sample_fn = sample_fn

        def sample(self, rng: random.Random):
            return self._sample_fn(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(seq) -> _Strategy:
            choices = list(seq)
            return _Strategy(lambda rng: rng.choice(choices))

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: rng.random() < 0.5)

    st = _Strategies()

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                rng = random.Random(1234)
                for _ in range(n):
                    draw = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*args, **draw, **kwargs)
            # hide strategy params from pytest's fixture resolution: the
            # wrapper's effective signature is the test minus drawn args
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            del wrapper.__wrapped__
            return wrapper
        return deco
