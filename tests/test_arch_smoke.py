"""Deliverable (f): per-assigned-architecture smoke tests — reduced config of
the same family, one forward + one train-grad step on CPU, output shapes +
no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.models import get_model

ARCHS = cfglib.ARCH_IDS


def _batch(cfg, b=2, s=16):
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (b, 8, cfg.frontend_dim), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = cfglib.get_smoke_config(arch)
    assert cfg.family == cfglib.get_config(arch).family
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss = jax.jit(lambda p: model.loss(p, cfg, batch))(params)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    grads = jax.jit(jax.grad(lambda p: model.loss(p, cfg, batch)))(params)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat), \
        f"{arch}: non-finite grads"
    gn = float(jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                            for g in flat)))
    assert gn > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = cfglib.get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    cache = model.init_cache(cfg, 2, 24)
    batch = _batch(cfg, s=8)
    if cfg.family in ("audio", "vlm"):
        logits, cache = jax.jit(
            lambda p, b, c: model.prefill(p, cfg, b, c))(params, batch, cache)
    else:
        logits, cache = jax.jit(
            lambda p, t, c: model.prefill(p, cfg, t, c))(
                params, batch["tokens"], cache)
    assert logits.shape[-1] == cfg.vocab
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    logits2, cache = jax.jit(
        lambda p, t, c: model.decode_step(p, cfg, t, c))(params, tok, cache)
    assert logits2.shape == (2, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits2)).all(), f"{arch}: NaN decode"


def test_exact_assigned_dimensions():
    """Configs must match the assignment table exactly."""
    expect = {
        "phi3.5-moe-42b-a6.6b": dict(n_layer=32, d_model=4096, n_head=32,
                                     n_kv_head=8, vocab=32064, n_experts=16,
                                     top_k=2, moe_d_ff=6400),
        "qwen2-moe-a2.7b": dict(n_layer=24, d_model=2048, n_head=16,
                                n_kv_head=16, vocab=151936, n_experts=60,
                                top_k=4, moe_d_ff=1408, n_shared_experts=4),
        "zamba2-7b": dict(n_layer=81, d_model=3584, n_head=32, n_kv_head=32,
                          d_ff=14336, vocab=32000, ssm_state=64),
        "glm4-9b": dict(n_layer=40, d_model=4096, n_head=32, n_kv_head=2,
                        d_ff=13696, vocab=151552),
        "qwen1.5-110b": dict(n_layer=80, d_model=8192, n_head=64, n_kv_head=8,
                             d_ff=49152, vocab=152064, qkv_bias=True),
        "deepseek-67b": dict(n_layer=95, d_model=8192, n_head=64, n_kv_head=8,
                             d_ff=22016, vocab=102400),
        "deepseek-coder-33b": dict(n_layer=62, d_model=7168, n_head=56,
                                   n_kv_head=8, d_ff=19200, vocab=32256),
        "mamba2-2.7b": dict(n_layer=64, d_model=2560, vocab=50280,
                            ssm_state=128),
        "whisper-medium": dict(n_layer=24, n_enc_layer=24, d_model=1024,
                               n_head=16, n_kv_head=16, d_ff=4096, vocab=51865),
        "internvl2-26b": dict(n_layer=48, d_model=6144, n_head=48,
                              n_kv_head=8, d_ff=16384, vocab=92553),
    }
    for arch, fields in expect.items():
        cfg = cfglib.get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"


def test_shape_applicability():
    assert cfglib.arch_shapes("mamba2-2.7b") == [
        "train_4k", "prefill_32k", "decode_32k", "long_500k"]
    assert cfglib.arch_shapes("zamba2-7b")[-1] == "long_500k"
    for arch in ("glm4-9b", "qwen1.5-110b", "whisper-medium",
                 "phi3.5-moe-42b-a6.6b"):
        assert "long_500k" not in cfglib.arch_shapes(arch)
    assert len(cfglib.ARCH_IDS) == 10
    total_cells = sum(len(cfglib.arch_shapes(a)) + (
        1 if "long_500k" not in cfglib.arch_shapes(a) else 0)
        for a in cfglib.ARCH_IDS)
    assert total_cells == 40  # 32 runnable + 8 documented skips
