"""Streaming ingest pipeline (DESIGN.md §13): source parsing, bucket
schedule, worker-count determinism, failure propagation, resume.

The load-bearing contract: the consumed stream is a pure function of
(seed, step) — worker count, thread scheduling, close/re-iterate and
resume-at-step-k must all be invisible in the values.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.config import af2_tiny
from repro.data import bucketing as bk
from repro.data.ingest import (
    FastaSource, GAP_ID, ProteinRecord, SyntheticSource, aa_ids, demo_fasta,
    featurize_record, parse_fasta, parse_mmcif_lite, synthesize_msa)
from repro.data.loader import ShardedLoader
from repro.data.pipeline import (
    DataPipeline, HostWorkerPool, TRAIN_BATCH_KEYS, WorkerFailure)

pytestmark = pytest.mark.data


def tiny_cfg(n_res=12, n_seq=4, n_extra_seq=6):
    return af2_tiny(n_evoformer=1, n_extra_msa_blocks=1, n_res=n_res,
                    n_seq=n_seq, n_extra_seq=n_extra_seq)


# ---------------------------------------------------------------------------
# ingest: parsers + featurize_record
# ---------------------------------------------------------------------------

def test_parse_fasta_multirecord_whitespace():
    text = ">a desc\nACDE\nFGH\n\n>b\n  MKV  \n"
    recs = parse_fasta(text)
    assert recs == [("a desc", "ACDEFGH"), ("b", "MKV")]
    with pytest.raises(ValueError):
        parse_fasta("ACDE\n>late header\n")


MMCIF_LITE = """\
data_demo
loop_
_atom_site.group_PDB
_atom_site.label_atom_id
_atom_site.label_comp_id
_atom_site.label_seq_id
_atom_site.Cartn_x
_atom_site.Cartn_y
_atom_site.Cartn_z
ATOM N   MET 1 0.0 0.0 0.0
ATOM CA  MET 1 1.0 2.0 3.0
ATOM CA  ALA 2 4.8 2.0 3.0
HETATM CA  HOH 3 9.9 9.9 9.9
ATOM CA  GLY 4 8.6 2.0 3.0
#
"""


def test_parse_mmcif_lite_ca_trace():
    seq, coords = parse_mmcif_lite(MMCIF_LITE)
    assert seq == "MAG"                       # HETATM water skipped
    np.testing.assert_allclose(coords[0], [1.0, 2.0, 3.0])
    assert coords.shape == (3, 3) and coords.dtype == np.float32
    with pytest.raises(ValueError):
        parse_mmcif_lite("data_x\nloop_\n_foo.bar\n1\n")


def test_featurize_record_shapes_and_determinism():
    cfg = tiny_cfg()
    seq = "ACDEFGHIK"
    rec = ProteinRecord(name="r", seq=seq,
                        msa=synthesize_msa(seq, 3,
                                           np.random.default_rng(0)))
    a = featurize_record(rec, cfg, seed=5, step=7, idx=1)
    b = featurize_record(rec, cfg, seed=5, step=7, idx=1)
    assert sorted(a) == sorted(TRAIN_BATCH_KEYS)
    r = len(seq)
    assert a["msa_feat"].shape == (cfg.n_seq, r, cfg.msa_feat_dim)
    assert a["extra_msa_feat"].shape == (cfg.n_extra_seq, r, cfg.msa_feat_dim)
    assert a["target_feat"].shape == (r, cfg.target_feat_dim)
    assert a["true_rots"].shape == (r, 3, 3)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    # different (step, idx) -> different mask draw, same truth
    c = featurize_record(rec, cfg, seed=5, step=8, idx=1)
    assert not np.array_equal(a["msa_mask_positions"],
                              c["msa_mask_positions"])
    np.testing.assert_array_equal(a["true_msa"], c["true_msa"])
    # frames orthonormal
    rr = np.einsum("rij,rik->rjk", a["true_rots"], a["true_rots"])
    np.testing.assert_allclose(rr, np.broadcast_to(np.eye(3), rr.shape),
                               atol=1e-4)


def test_fasta_source_lengths_and_structures():
    cfg = tiny_cfg()
    src = FastaSource(demo_fasta(cfg, n_records=5, seed=3), cfg,
                      is_path=False)
    assert len(src) == 5
    for i in range(len(src)):
        rec = src.record(i)
        assert src.record_length(i) == rec.n_res <= cfg.n_res
        assert len(rec.msa) == cfg.n_seq
    # a supplied structure overrides the synthetic chain
    seq, coords = parse_mmcif_lite(MMCIF_LITE)
    src2 = FastaSource(f">s\n{seq}\n", cfg, structures={"s": coords},
                       is_path=False)
    np.testing.assert_array_equal(src2.record(0).coords, coords)


# ---------------------------------------------------------------------------
# bucketing: schedule determinism + coverage
# ---------------------------------------------------------------------------

def test_bucket_schedule_deterministic_and_covering():
    cfg = tiny_cfg(n_res=16)
    src = SyntheticSource(cfg, seed=0, n_records=11, vary_length=True)
    lengths = [src.record_length(i) for i in range(len(src))]
    buckets = bk.length_bucket_table(cfg)
    s1 = bk.BucketSchedule(lengths, buckets, seed=4, batch_size=3)
    s2 = bk.BucketSchedule(lengths, buckets, seed=4, batch_size=3)
    e1, e2 = s1.plan_epoch(2), s2.plan_epoch(2)
    assert e1 == e2 and len(e1) == s1.per_epoch
    # every record appears in its epoch; every batch is homogeneous in
    # bucket and full-size (tail wraps within the bucket)
    seen = set()
    for plan in e1:
        assert len(plan.indices) == 3
        for i in plan.indices:
            seen.add(i)
            assert lengths[i] <= plan.bucket.n_res
    assert seen == set(range(11))
    # epochs differ (it IS a shuffle) but per_epoch stays fixed
    assert s1.plan_epoch(0) != s1.plan_epoch(1)
    # global step -> epoch tiling
    assert s1.batch_plan(s1.per_epoch + 2) == s1.plan_epoch(1)[2]


def test_bucket_for_length_and_pad_record():
    cfg = tiny_cfg(n_res=16)
    buckets = bk.length_bucket_table(cfg)
    assert bk.bucket_for_length(buckets, 3).n_res == 8
    with pytest.raises(ValueError):
        bk.bucket_for_length(buckets, 999)
    rec = SyntheticSource(cfg, seed=1, n_records=2,
                          vary_length=True).record(0)
    feats = featurize_record(rec, cfg, seed=0, step=0, idx=0)
    padded = bk.pad_record_to_bucket(feats, bk.train_bucket(cfg))
    r = rec.n_res
    assert padded["target_feat"].shape == (16, cfg.target_feat_dim)
    assert np.all(padded["res_mask"][r:] == 0)
    assert np.all(padded["true_msa"][:, r:] == GAP_ID)
    assert not padded["msa_mask_positions"][:, r:].any()
    # padded frames stay orthonormal (identity), so geometry stays finite
    rr = np.einsum("rij,rik->rjk", padded["true_rots"], padded["true_rots"])
    np.testing.assert_allclose(rr, np.broadcast_to(np.eye(3), rr.shape),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# HostWorkerPool + ShardedLoader failure propagation (the silent-hang fix)
# ---------------------------------------------------------------------------

def test_host_worker_pool_inline_and_threaded_failures():
    def fn(x):
        if x < 0:
            raise ValueError("bad item")
        return x * 2

    inline = HostWorkerPool(fn, workers=0)
    inline.submit(3)
    assert inline.poll() == [6]
    inline.submit(-1)
    (fail,) = inline.poll()
    assert isinstance(fail, WorkerFailure)
    inline.submit(-1)
    with pytest.raises(ValueError, match="bad item"):
        inline.poll(raise_failures=True)

    pool = HostWorkerPool(fn, workers=2, cap=4)
    for x in (1, 2, -1, 3):
        pool.submit(x)
    got, deadline = [], time.monotonic() + 10
    while len(got) < 4 and time.monotonic() < deadline:
        got.extend(pool.poll(block=True, timeout=1.0))
    pool.close()
    vals = [g for g in got if not isinstance(g, WorkerFailure)]
    fails = [g for g in got if isinstance(g, WorkerFailure)]
    assert sorted(vals) == [2, 4, 6] and len(fails) == 1


def test_sharded_loader_worker_exception_propagates():
    """A make_batch exception must re-raise from the iterator, not leave
    the consumer blocked on q.get() forever (the silent-hang bug)."""
    def make_batch(step):
        if step == 2:
            raise RuntimeError("synthetic corruption at step 2")
        return {"x": np.full((2,), step)}

    loader = ShardedLoader(make_batch, start_step=0, prefetch=2)
    got = []

    def consume():
        with pytest.raises(RuntimeError, match="step 2"):
            for step, b in loader:
                got.append(step)

    t = threading.Thread(target=consume, daemon=True)
    t.start()
    t.join(timeout=10.0)
    assert not t.is_alive(), "consumer hung on a dead worker"
    assert got == [0, 1]


def test_pipeline_worker_exception_propagates():
    cfg = tiny_cfg()

    def make_batch(step):
        if step == 3:
            raise ValueError("boom at 3")
        from repro.data.protein import protein_batch
        return protein_batch(0, step, 1, cfg)

    pipe = DataPipeline(cfg, make_batch=make_batch, workers=2)
    got = []
    with pytest.raises(RuntimeError, match="failed at step 3") as ei:
        for step, b in pipe:
            got.append(step)
    assert isinstance(ei.value.__cause__, ValueError)
    # failures are delivered in stream order: every prior step still yields
    assert got == [0, 1, 2]


# ---------------------------------------------------------------------------
# DataPipeline determinism: worker count, re-iterate, resume
# ---------------------------------------------------------------------------

def _collect(pipe, n):
    out = []
    for step, batch in pipe:
        out.append((step, {k: np.asarray(v) for k, v in batch.items()}))
        if len(out) >= n:
            break
    pipe.close()
    return out


def _assert_streams_equal(a, b):
    assert [s for s, _ in a] == [s for s, _ in b]
    for (_, x), (_, y) in zip(a, b):
        assert sorted(x) == sorted(y)
        for k in x:
            np.testing.assert_array_equal(x[k], y[k])


def test_pipeline_bit_identical_across_worker_counts():
    cfg = tiny_cfg(n_res=16)
    streams = []
    for workers in (0, 1, 4):
        src = SyntheticSource(cfg, seed=0, n_records=10, vary_length=True)
        pipe = DataPipeline(cfg, source=src, batch_size=2, seed=0,
                            workers=workers, bucket_by_length=True,
                            pad_to=bk.train_bucket(cfg))
        streams.append(_collect(pipe, 8))
    _assert_streams_equal(streams[0], streams[1])
    _assert_streams_equal(streams[0], streams[2])
    # training batches carry exactly the protein_sample contract
    assert sorted(streams[0][0][1]) == sorted(TRAIN_BATCH_KEYS)


def test_pipeline_compat_matches_protein_batch():
    from repro.data.protein import protein_batch
    cfg = tiny_cfg()
    pipe = DataPipeline(cfg, batch_size=2, seed=11, workers=2)
    for step, batch in _collect(pipe, 4):
        ref = protein_batch(11, step, 2, cfg)
        for k in ref:
            np.testing.assert_array_equal(np.asarray(batch[k]),
                                          np.asarray(ref[k]))


def test_pipeline_close_reiterate_and_resume():
    cfg = tiny_cfg(n_res=16)

    def fresh(start_step=0, workers=3):
        src = SyntheticSource(cfg, seed=2, n_records=9, vary_length=True)
        return DataPipeline(cfg, source=src, batch_size=2, seed=2,
                            start_step=start_step, workers=workers,
                            bucket_by_length=True,
                            pad_to=bk.train_bucket(cfg))

    pipe = fresh()
    first = _collect(pipe, 6)
    pipe2 = fresh()
    it = iter(pipe2)
    with pytest.raises(RuntimeError, match="already being iterated"):
        iter(pipe2)
    pipe2.close()
    second = _collect(pipe2, 6)          # close -> re-iterate works
    _assert_streams_equal(first, second)
    # resume at step 3 reproduces the fresh run's tail bit-for-bit
    resumed = _collect(fresh(start_step=3, workers=1), 3)
    _assert_streams_equal(first[3:], resumed)


def test_pipeline_bucket_by_length_needs_source():
    cfg = tiny_cfg()
    with pytest.raises(ValueError, match="record source"):
        DataPipeline(cfg, bucket_by_length=True)


def test_pipeline_report_accounts_steps():
    cfg = tiny_cfg()
    src = SyntheticSource(cfg, seed=0, n_records=6, vary_length=True)
    pipe = DataPipeline(cfg, source=src, batch_size=2, seed=0, workers=2,
                        bucket_by_length=True, pad_to=bk.train_bucket(cfg))
    _collect(pipe, 5)
    d = pipe.report.as_dict()
    assert d["steps"] >= 5
    assert 0.0 < d["mean_fill"] <= 1.0
    assert d["stall_ms_per_step"] >= 0.0
    assert sum(d["buckets"].values()) == pipe.report.batches


# ---------------------------------------------------------------------------
# TrainRunner: the pipeline behind the real compiled loop
# ---------------------------------------------------------------------------

def test_trainer_losses_bit_identical_across_workers():
    from repro.train.trainer import TrainRunner
    cfg = af2_tiny(n_evoformer=1, n_extra_msa_blocks=1, n_res=8, n_seq=4,
                   n_extra_seq=6)
    losses = []
    for workers in (0, 2):
        r = TrainRunner(cfg, batch_size=2, seed=0, recycle_sample=False,
                        ema_decay=None, data_workers=workers)
        hist = r.run(2)
        losses.append(hist["loss"])
        assert hist["data"][-1]["steps"] >= 2
    assert losses[0] == losses[1]
