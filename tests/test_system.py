"""End-to-end system behaviour: the public launchers actually train/serve."""
import os
import subprocess
import sys

import pytest

from tests.util import _repo_root

pytestmark = pytest.mark.slow


def _run(args, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", *args], capture_output=True, text=True,
        timeout=timeout, cwd=_repo_root(), env=env)
    assert proc.returncode == 0, (
        f"{args} failed:\n{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
    return proc.stdout


def test_train_af2_tiny_end_to_end(tmp_path):
    out = _run(["repro.launch.train", "--af2", "tiny", "--steps", "3",
                "--batch", "2", "--ckpt-dir", str(tmp_path / "ck"),
                "--ckpt-every", "2"])
    assert "done: 3 steps" in out
    # checkpoint written and resumable
    out2 = _run(["repro.launch.train", "--af2", "tiny", "--steps", "4",
                 "--batch", "2", "--ckpt-dir", str(tmp_path / "ck"),
                 "--resume"])
    assert "resumed from step" in out2


def test_train_af2_tiny_bp_on_fake_devices():
    out = _run(["repro.launch.train", "--af2", "tiny", "--steps", "2",
                "--batch", "4", "--devices", "4", "--bp", "2"])
    assert "done: 2 steps" in out
    assert "'branch': 2" in out


def test_train_lm_smoke():
    out = _run(["repro.launch.train", "--arch", "mamba2-2.7b", "--smoke",
                "--steps", "3", "--batch", "2", "--seq", "32"])
    assert "loss" in out and "done" in out


def test_serve_smoke():
    out = _run(["repro.launch.serve", "--arch", "glm4-9b", "--smoke",
                "--requests", "3", "--slots", "2", "--max-new", "4",
                "--prompt-len", "8", "--max-len", "32"])
    assert "served 3 requests" in out
