"""Quickstart: the paper's contribution in ~40 lines.

Builds a tiny AlphaFold2 with the Parallel Evoformer block (paper Fig. 1c),
takes one training step, then shows the drop-in Branch-Parallel block being
numerically identical (run with REPRO_DEVICES=2 to actually split branches
over two devices).

  PYTHONPATH=src python examples/quickstart.py
  REPRO_DEVICES=2 PYTHONPATH=src python examples/quickstart.py
"""
import os

if os.environ.get("REPRO_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DEVICES"])

import jax
import jax.numpy as jnp

from repro.core import model as af2
from repro.core.config import af2_tiny
from repro.data.protein import protein_sample
from repro.train.optim import adamw

cfg = af2_tiny(variant="parallel")          # OPM at the END of the block
params = af2.init_params(jax.random.PRNGKey(0), cfg)
print(f"params: {sum(x.size for x in jax.tree_util.tree_leaves(params)):,}")

batch = protein_sample(jax.random.PRNGKey(1), cfg)
loss, metrics = jax.jit(lambda p, b: af2.loss_fn(p, cfg, b))(params, batch)
print("losses:", {k: round(float(v), 3) for k, v in metrics.items()})

opt = adamw(1e-3, clip_norm=0.1)
state = opt.init(params)
grads = jax.jit(jax.grad(lambda p: af2.loss_fn(p, cfg, batch)[0]))(params)
params, state = opt.update(grads, state, params)
print("one optimizer step done")

# Branch Parallelism: same math, two devices — declared via a ParallelPlan
if len(jax.devices()) >= 2:
    from jax.sharding import PartitionSpec as P
    from repro.parallel.mesh_utils import smap
    from repro.parallel.plan import ParallelPlan

    built = ParallelPlan(branch=2).build(jax.devices()[:2], cfg=cfg)
    e = cfg.evoformer
    msa = jnp.asarray(batch["msa_feat"][:, :, :e.c_m], jnp.float32)
    z = jax.random.normal(jax.random.PRNGKey(2), (cfg.n_res, cfg.n_res, e.c_z))
    blk = af2.stack_init(jax.random.PRNGKey(3), e, 1, scan=True)
    serial = jax.jit(lambda p, m, zz: af2.evoformer_stack(
        p, e, 1, m, zz, scan=True, remat=False))(blk, msa, z)
    bp = jax.jit(smap(lambda p, m, zz: af2.evoformer_stack(
        p, e, 1, m, zz, scan=True, remat=False, block_fn=built.block_fn),
        built.mesh, (P(), P(), P()), (P(), P())))(blk, msa, z)
    diff = max(float(jnp.abs(a - b).max()) for a, b in zip(serial, bp))
    print(f"BP=2 vs serial max |diff| = {diff:.2e}  (Branch Parallelism is "
          "exact, paper §4.2)")
else:
    print("run with REPRO_DEVICES=2 to see Branch Parallelism split")
