"""End-to-end AlphaFold2 training driver (paper reproduction scale knobs).

Defaults are CPU-runnable; ``--preset small`` is a ~20M-param model (half
the channel widths / 2/3 the depth of model-1 at full initial-training data
shapes), ``--preset paper`` is the full 93M model-1 recipe (BP=2 x DAP
across the model axis on a real pod).  Demonstrates the full stack:
synthetic protein pipeline -> Parallel Evoformer -> a ParallelPlan-built
BP/DAP/DP shard_map step -> Adam + AF2 LR schedule -> checkpoint/restart
(with plan metadata) + straggler watchdog.

  PYTHONPATH=src python examples/train_af2.py --steps 5
  PYTHONPATH=src python examples/train_af2.py --devices 8 --bp 2 --dap 2 \
      --batch 8 --steps 5
  PYTHONPATH=src python examples/train_af2.py --devices 8 --auto-plan \
      --batch 4 --steps 5
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--preset", default="tiny", choices=["tiny", "small", "paper"])
ap.add_argument("--steps", type=int, default=5)
ap.add_argument("--batch", type=int, default=2)
ap.add_argument("--devices", type=int, default=0)
ap.add_argument("--bp", type=int, default=1)
ap.add_argument("--dap", type=int, default=1)
ap.add_argument("--auto-plan", action="store_true",
                help="roofline-driven DP x BP x DAP selection "
                     "(repro.parallel.plan.auto_plan)")
ap.add_argument("--ckpt-dir", default="/tmp/af2_ckpt")
ap.add_argument("--recycle-sample", action="store_true",
                help="stochastic recycling (one compiled step serves all "
                     "per-step n_recycle draws)")
ap.add_argument("--max-recycle", type=int, default=0,
                help="recycle-sampling upper bound (0 = cfg.max_recycle)")
ap.add_argument("--eval-every", type=int, default=0,
                help="EMA-eval lDDT-Cα cadence on the held-out split")
ap.add_argument("--ema", type=float, default=0.999,
                help="EMA decay for eval params (0 disables)")
ap.add_argument("--seed", type=int, default=0)
ap.add_argument("--data-workers", type=int, default=1,
                help="host featurize worker threads (0 = inline, no overlap)")
ap.add_argument("--data-source", default="synthetic",
                choices=["synthetic", "fasta"],
                help="input source: deterministic synthetic stream or the "
                     "FASTA record-ingest path")
ap.add_argument("--fasta", default="",
                help="FASTA file for --data-source fasta (empty = bundled "
                     "demo records)")
ap.add_argument("--bucket-by-length", action="store_true",
                help="length-bucketed shuffle (record sources only)")
args = ap.parse_args()

if args.devices:
    os.environ["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                               f"{args.devices}")

sys.argv = [sys.argv[0], "--af2", {"tiny": "tiny", "small": "small",
                                   "paper": "initial"}[args.preset],
            "--steps", str(args.steps), "--batch", str(args.batch),
            "--bp", str(args.bp), "--dap", str(args.dap),
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50"]
if args.auto_plan:
    sys.argv += ["--auto-plan"]
if args.devices:
    sys.argv += ["--devices", str(args.devices)]
if args.recycle_sample:
    sys.argv += ["--recycle-sample"]
if args.eval_every:
    sys.argv += ["--eval-every", str(args.eval_every)]
if args.max_recycle:
    sys.argv += ["--max-recycle", str(args.max_recycle)]
if args.fasta:
    sys.argv += ["--fasta", args.fasta]
if args.bucket_by_length:
    sys.argv += ["--bucket-by-length"]
sys.argv += ["--ema", str(args.ema), "--seed", str(args.seed),
             "--data-workers", str(args.data_workers),
             "--data-source", args.data_source]

from repro.launch.train import main  # noqa: E402

main()
