"""Train a ~100M-parameter dense LM end-to-end on the synthetic token
pipeline — the framework's GSPMD training path at a CPU-runnable scale.

  PYTHONPATH=src python examples/lm_train.py --steps 200
(defaults are sized so a few hundred steps complete on a single CPU;
the identical code path drives the 110B assigned config on the pod.)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.data.loader import ShardedLoader
from repro.data.tokens import token_batch
from repro.models import dense
from repro.models.lmconfig import LMConfig
from repro.train.checkpoint import CheckpointManager, StepWatchdog
from repro.train.optim import adamw, warmup_cosine
from repro.train.trainstep import make_lm_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--d-model", type=int, default=640)
ap.add_argument("--layers", type=int, default=10)
ap.add_argument("--vocab", type=int, default=32000)
ap.add_argument("--ckpt-dir", default="")
args = ap.parse_args()

cfg = LMConfig(arch_id="lm100m", family="dense", n_layer=args.layers,
               d_model=args.d_model, n_head=args.d_model // 64,
               n_kv_head=max(2, args.d_model // 128), d_ff=4 * args.d_model,
               vocab=args.vocab, scan_layers=True, remat="none",
               attention_chunk=128)
model = dense
mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
opt = adamw(warmup_cosine(3e-4, 20, args.steps), clip_norm=1.0)
step_fn, _, _ = make_lm_train_step(model, cfg, opt, mesh)

params = model.init_params(jax.random.PRNGKey(0), cfg)
n = sum(x.size for x in jax.tree_util.tree_leaves(params))
print(f"params: {n:,} (~{n/1e6:.0f}M)")
state = {"params": params, "opt": opt.init(params)}
fn = jax.jit(step_fn, donate_argnums=(0,))

mgr = CheckpointManager(args.ckpt_dir, keep=2) if args.ckpt_dir else None
wd = StepWatchdog()


def make_batch(step):
    b = token_batch(0, step, args.batch, args.seq, cfg.vocab)
    return {"tokens": jnp.asarray(b["tokens"]),
            "labels": jnp.asarray(b["labels"])}


loader = ShardedLoader(make_batch)
t0 = time.time()
try:
    for step, batch in loader:
        if step >= args.steps:
            break
        wd.start_step()
        state, m = fn(state, batch)
        wd.end_step(step)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"({args.batch * args.seq / max(wd.ema or 1, 1e-9):,.0f} tok/s)")
        if mgr and step and step % 100 == 0:
            mgr.save(step, state)
finally:
    loader.close()
if mgr:
    mgr.save(args.steps, state)
    mgr.wait()
print(f"trained {args.steps} steps in {time.time()-t0:.0f}s")
