"""Serve a small model with batched requests through the DecodeEngine
(continuous-batching slots, KV-cache reuse).

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.models import dense
from repro.models.lmconfig import LMConfig
from repro.serve.engine import DecodeEngine, Request

cfg = LMConfig(arch_id="demo", family="dense", n_layer=4, d_model=256,
               n_head=4, n_kv_head=2, d_ff=512, vocab=5003,
               scan_layers=True, remat="none", attention_chunk=64)
params = dense.init_params(jax.random.PRNGKey(0), cfg)
print(f"params: {sum(x.size for x in jax.tree_util.tree_leaves(params)):,}")

engine = DecodeEngine(dense, cfg, params, batch_slots=4, max_len=96)
rng = np.random.default_rng(0)
requests = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 12,
                                               dtype=np.int32),
                    max_new_tokens=16) for i in range(10)]
t0 = time.time()
done = engine.run(requests)
dt = time.time() - t0
tokens = sum(len(v) for v in done.values())
print(f"served {len(done)} requests / {tokens} tokens in {dt:.1f}s "
      f"({tokens/dt:.1f} tok/s, 4 slots, continuous batching)")
print("sample:", done[0])
